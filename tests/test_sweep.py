"""Sweep engine + scenario library: determinism, batched-vs-scalar
bit-identity for every registered policy, the jax/pallas fast paths, and
the grid-vs-loop speed smoke."""
import time

import numpy as np
import pytest

from repro.core.policy import PolicyBase, list_policies, register_policy
from repro.core.policy.registry import _REGISTRY
from repro.core.refresh.scenarios import (Trace, list_scenarios, make_trace,
                                          register_scenario)
from repro.core.sweep import CellResult, SweepSpec, sweep

SMALL = dict(densities=(32,), reqs=120, seed=3)
BUILTIN_SCENARIOS = ("read_heavy", "write_burst_draining",
                     "row_buffer_friendly", "bank_camping",
                     "subarray_conflict_adversarial", "trace_replay",
                     "mixed", "streaming")


def _cells_equal(a, b):
    bad = [(x.policy, x.scenario, x.density_gb, f)
           for x, y in zip(a.cells, b.cells) if x != y
           for f in CellResult.__dataclass_fields__
           if getattr(x, f) != getattr(y, f)]
    assert not bad, f"backends diverged: {bad[:8]}"


# ------------------------------------------------------- scenario library
def test_scenario_registry_lists_builtins():
    names = list_scenarios()
    for s in BUILTIN_SCENARIOS:
        assert s in names, s


def test_unknown_scenario_error_lists_known_names():
    with pytest.raises(KeyError, match="unknown scenario"):
        make_trace("nope_not_a_scenario")
    with pytest.raises(KeyError, match="read_heavy"):
        make_trace("nope_not_a_scenario")


@pytest.mark.parametrize("name", BUILTIN_SCENARIOS)
def test_scenario_deterministic_under_fixed_seed(name):
    a = make_trace(name, reqs=300, seed=7)
    b = make_trace(name, reqs=300, seed=7)
    for f in ("arrive", "bank", "row", "sub", "is_write"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    # validate() ran inside make_trace; spot-check the invariants anyway
    assert (np.diff(a.arrive) >= 0).all()
    assert a.bank.max() < a.n_banks and a.sub.max() < a.n_subarrays


@pytest.mark.parametrize("name", [s for s in BUILTIN_SCENARIOS
                                  if s != "trace_replay"])
def test_scenario_seed_changes_trace(name):
    a = make_trace(name, reqs=300, seed=1)
    b = make_trace(name, reqs=300, seed=2)
    assert any(not np.array_equal(getattr(a, f), getattr(b, f))
               for f in ("arrive", "bank", "row", "is_write")), name


def test_scenarios_shared_across_grid_axes():
    """One trace per (scenario, seed): every policy/density cell of a
    scenario must see identical workloads (comparability)."""
    res = sweep(SweepSpec(policies=("ideal",), scenarios=("mixed",),
                          densities=(8, 32), reqs=100, seed=0))
    a, b = res.get("ideal", "mixed", 8), res.get("ideal", "mixed", 32)
    assert a.reads_done + a.writes_done == b.reads_done + b.writes_done


def test_trace_replay_accepts_explicit_trace():
    tr = make_trace("trace_replay", reqs=8, trace=dict(
        arrive=[0, 2, 4, 9], bank=[0, 1, 0, 1], row=[5, 6, 5, 6],
        is_write=[False, True, False, False]))
    assert isinstance(tr, Trace) and len(tr) == 4
    assert list(tr.sub) == [r % 8 for r in (5, 6, 5, 6)]


# -------------------------------------------- batched vs scalar identity
def test_batched_matches_scalar_3x3_grid():
    """The acceptance grid: 3 policies x 3 scenarios, bit-identical."""
    spec = SweepSpec(policies=("ref_pb", "darp", "dsarp"),
                     scenarios=("read_heavy", "bank_camping",
                                "write_burst_draining"), **SMALL)
    _cells_equal(sweep(spec, "batched"), sweep(spec, "scalar"))


def test_batched_matches_scalar_all_registered_policies():
    """Every registered policy (paper family, aliases, extras) must give
    bit-identical stats through the vectorized path and the real
    per-policy select()."""
    spec = SweepSpec(policies=tuple(list_policies()),
                     scenarios=("mixed", "write_burst_draining"), **SMALL)
    _cells_equal(sweep(spec, "batched"), sweep(spec, "scalar"))


def test_custom_policy_falls_back_and_stays_identical():
    @register_policy("_test_sweep_greedy")
    class _Greedy(PolicyBase):
        def select(self, view):
            from repro.core.policy import Decision
            lag = list(view.lag)
            picks = []
            self._forced(view, lag, picks)
            owed = sorted((b for b in range(view.n_banks)
                           if view.ready[b] and lag[b] > 0),
                          key=lambda b: -lag[b])
            for b in owed[:max(0, view.max_issues - len(picks))]:
                picks.append(Decision(b))
            return picks
    try:
        spec = SweepSpec(policies=("_test_sweep_greedy", "darp"),
                         scenarios=("mixed",), **SMALL)
        rb, rs = sweep(spec, "batched"), sweep(spec, "scalar")
        _cells_equal(rb, rs)
        assert rb.get("_test_sweep_greedy", "mixed", 32).refreshes_pb > 0
    finally:
        del _REGISTRY["_test_sweep_greedy"]


def test_budget_invariant_across_grid():
    spec = SweepSpec(policies=("ref_pb", "darp", "dsarp", "elastic",
                               "hira"),
                     scenarios=("streaming", "bank_camping"), **SMALL)
    for cell in sweep(spec):
        assert cell.finished, (cell.policy, cell.scenario)
        assert cell.max_abs_lag <= 8, (cell.policy, cell.scenario,
                                       cell.max_abs_lag)
        assert cell.refreshes_pb > 0, (cell.policy, cell.scenario)


def test_sweep_result_indexing():
    spec = SweepSpec(policies=("ideal", "ref_pb"),
                     scenarios=("mixed", "read_heavy"),
                     densities=(8, 32), reqs=80, seed=1)
    res = sweep(spec)
    assert res.stat("reads_done").shape == (2, 2, 2)
    cell = res.get("ref_pb", "read_heavy", 32)
    assert cell.policy == "ref_pb" and cell.density_gb == 32
    assert res.get("ideal", "mixed", 8).refreshes_pb == 0


def test_sarp_orderings_on_adversarial_scenario():
    """SARP pays on conflict-free traffic and loses its edge when accesses
    chase the refreshing subarray."""
    spec = SweepSpec(policies=("ref_pb", "sarp_pb"),
                     scenarios=("read_heavy",
                                "subarray_conflict_adversarial"),
                     densities=(32,), reqs=400, seed=0)
    res = sweep(spec)
    friendly = (res.get("sarp_pb", "read_heavy", 32).avg_read_latency
                / res.get("ref_pb", "read_heavy", 32).avg_read_latency)
    adv = (res.get("sarp_pb", "subarray_conflict_adversarial", 32)
           .avg_read_latency
           / res.get("ref_pb", "subarray_conflict_adversarial", 32)
           .avg_read_latency)
    assert friendly <= 1.01          # SARP never much worse when friendly
    assert adv >= friendly - 0.02    # adversarial erodes the advantage


# ----------------------------------------------------- jax / pallas paths
def test_jax_backend_bit_identical():
    spec = SweepSpec(policies=("ref_ab", "ref_pb", "darp", "dsarp",
                               "elastic", "hira", "ideal"),
                     scenarios=("mixed", "write_burst_draining"), **SMALL)
    _cells_equal(sweep(spec, "jax"), sweep(spec, "scalar"))


def test_jax_backend_rejects_custom_policies():
    @register_policy("_test_sweep_nojit")
    class _NoJit(PolicyBase):
        def select(self, view):
            return []
    try:
        spec = SweepSpec(policies=("_test_sweep_nojit",),
                         scenarios=("mixed",), **SMALL)
        with pytest.raises(ValueError, match="backend='batched'"):
            sweep(spec, "jax")
    finally:
        del _REGISTRY["_test_sweep_nojit"]


def test_empty_axis_spec_rejected_with_clear_error():
    with pytest.raises(ValueError, match="at least one policy"):
        sweep(SweepSpec(policies=("darp",), scenarios=()))
    with pytest.raises(ValueError, match="at least one policy"):
        sweep(SweepSpec(policies=(), scenarios=("mixed",)))


def test_masked_scores_match_shared():
    """The batched backend's mask-based fast scoring must stay in
    lock-step with the shared `arbiter_scores` definition."""
    from repro.core.sweep.arbiter import arbiter_scores, arbiter_scores_masked

    rs = np.random.RandomState(23)
    G, B = 64, 8
    for t in (0, 311, 5000):
        kw = dict(
            has_req=rs.rand(G, B) < 0.7,
            head_row=rs.randint(0, 4096, (G, B)).astype(np.int32),
            head_arrive=rs.randint(0, max(1, t + 1), (G, B)).astype(np.int32),
            head_is_write=rs.rand(G, B) < 0.3,
            bank_free=rs.randint(0, 700, (G, B)).astype(np.int32),
            # the head subarray's refresh-end tick + the bank-level
            # any-subarray-mid-refresh plane (gathered by the engine)
            head_ref_until=rs.randint(0, 700, (G, B)).astype(np.int32),
            bank_mid_ref=rs.rand(G, B) < 0.3,
            open_row=rs.randint(-1, 4096, (G, B)).astype(np.int32),
            drain=rs.rand(G) < 0.4,
            # per-bank rank-drain plane (each bank carries its rank's flag)
            rank_drain=np.repeat(rs.rand(G, 2) < 0.1, B // 2, axis=1),
            occ=rs.randint(0, 20, (G, B)).astype(np.int32),
        )
        expect = arbiter_scores(np, t, **kw)
        got = arbiter_scores_masked(
            t, has_req=kw["has_req"], idle=kw["bank_free"] <= t,
            head_ready=kw["head_ref_until"] <= t,
            bank_mid_ref=kw["bank_mid_ref"], head_row=kw["head_row"],
            head_arrive=kw["head_arrive"],
            head_is_write=kw["head_is_write"],
            open_row=kw["open_row"], drain=kw["drain"],
            rank_drain=np.asarray(kw["rank_drain"]),
            rank_can_drain=True, occ=kw["occ"])
        np.testing.assert_array_equal(np.asarray(got, np.int64),
                                      np.asarray(expect, np.int64), str(t))


def test_pallas_arbiter_matches_numpy_scores():
    from repro.core.sweep.arbiter import arbiter_scores
    from repro.kernels.sweep_arbiter import make_arbiter

    rs = np.random.RandomState(11)
    G, B = 37, 8                      # deliberately not a tile multiple
    kw = dict(
        has_req=rs.rand(G, B) < 0.7,
        head_row=rs.randint(0, 4096, (G, B)).astype(np.int32),
        head_arrive=rs.randint(0, 500, (G, B)).astype(np.int32),
        head_is_write=rs.rand(G, B) < 0.3,
        bank_free=rs.randint(0, 700, (G, B)).astype(np.int32),
        head_ref_until=rs.randint(0, 700, (G, B)).astype(np.int32),
        bank_mid_ref=rs.rand(G, B) < 0.3,
        open_row=rs.randint(-1, 4096, (G, B)).astype(np.int32),
        drain=rs.rand(G) < 0.4,
        # per-bank rank-drain plane (each bank carries its rank's flag)
        rank_drain=np.repeat(rs.rand(G, 2) < 0.1, B // 2, axis=1),
    )
    t = 512
    expect = arbiter_scores(np, t, **kw)
    got = make_arbiter(G, B)(t, **kw)
    np.testing.assert_array_equal(np.asarray(got), expect)
    # occupancy field (closed-loop mode) must match through the kernel too
    occ = rs.randint(0, 20, (G, B)).astype(np.int32)
    expect_occ = arbiter_scores(np, t, occ=occ, **kw)
    got_occ = make_arbiter(G, B)(t, occ=occ, **kw)
    np.testing.assert_array_equal(np.asarray(got_occ), expect_occ)


def test_batched_with_pallas_arbiter_identical():
    spec = SweepSpec(policies=("ref_pb", "dsarp"), scenarios=("mixed",),
                     densities=(32,), reqs=80, seed=5)
    _cells_equal(sweep(spec, "batched", arbiter="pallas"),
                 sweep(spec, "scalar"))


# ------------------------------------------------------------ speed smoke
@pytest.mark.slow
def test_batched_grid_beats_scalar_loop():
    """Wall-clock smoke at a reduced grid; the full 8x8x3 acceptance
    numbers live in benchmarks/run.py -> results/bench/sweep_grid.json
    (batched is ~3x the tick oracle and >10x the legacy DramSim loop
    there). Threshold kept loose for CI noise."""
    spec = SweepSpec(policies=("ideal", "ref_ab", "ref_pb", "darp",
                               "darp_ooo", "sarp_pb", "dsarp", "elastic"),
                     scenarios=("read_heavy", "write_burst_draining",
                                "bank_camping", "streaming"),
                     densities=(8, 32), reqs=150, seed=0)
    t0 = time.perf_counter()
    rb = sweep(spec, "batched")
    t_b = time.perf_counter() - t0
    t0 = time.perf_counter()
    rs = sweep(spec, "scalar")
    t_s = time.perf_counter() - t0
    _cells_equal(rb, rs)
    assert t_b < t_s, (t_b, t_s)
