"""End-to-end behaviour tests: every assigned architecture's REDUCED config
runs a forward/backward train step, prefill, and decode on CPU with finite
outputs and correct shapes (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced
from repro.common.config import list_archs
from repro.models.api import get_model

B, S = 2, 32


def _batch(cfg):
    if cfg.family == "encdec":
        return {"enc_embeds": jnp.ones((B, S, cfg.d_model), jnp.float32) * 0.01,
                "tokens": jnp.ones((B, S), jnp.int32),
                "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend == "embed":
        b = {"embeds": jnp.ones((B, S, cfg.d_model), jnp.float32) * 0.01,
             "labels": jnp.ones((B, S), jnp.int32)}
        if cfg.attention and cfg.attention.mrope:
            b["positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
        return b
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke(arch, rng):
    cfg, dims = reduced(arch)
    mod = get_model(cfg)
    params = mod.init(rng, cfg, dims)
    batch = _batch(cfg)

    loss, metrics = jax.jit(lambda p, b: mod.train_loss(p, b, cfg, dims))(
        params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert float(metrics["tokens"]) > 0

    grads = jax.jit(jax.grad(lambda p, b: mod.train_loss(p, b, cfg, dims)[0]))(
        params, batch)
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g)))

    pf = dict(batch)
    pf.pop("labels")
    logits, state = jax.jit(lambda p, b: mod.prefill(p, b, cfg, dims))(
        params, pf)
    assert logits.shape == (B, dims.vocab)
    assert np.all(np.isfinite(np.asarray(logits)[:, :cfg.vocab_size]))

    st = mod.init_decode_state(cfg, dims, B, S)
    kw = ({"embed": jnp.ones((B, cfg.d_model), jnp.float32) * 0.01}
          if cfg.frontend == "embed" and cfg.family != "encdec"
          else {"token": jnp.ones((B,), jnp.int32)})
    lg, st2 = jax.jit(
        lambda p, s: mod.decode_step(p, s, cfg, dims, pos=jnp.int32(3), **kw))(
        params, st)
    assert lg.shape == (B, dims.vocab)
    assert np.all(np.isfinite(np.asarray(lg)[:, :cfg.vocab_size]))
    assert jax.tree.structure(st2) == jax.tree.structure(st)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-130m", "zamba2-7b"])
def test_decode_matches_forward(arch, rng):
    """Token-by-token decode must reproduce the full-forward logits."""
    cfg, dims = reduced(arch)
    mod = get_model(cfg)
    params = mod.init(rng, cfg, dims)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)

    # full forward last-position logits via prefill
    logits_pf, _ = mod.prefill(params, {"tokens": toks}, cfg, dims)

    # token-by-token decode over the same prefix
    st = mod.init_decode_state(cfg, dims, B, 8)
    lg = None
    for i in range(8):
        lg, st = mod.decode_step(params, st, cfg, dims,
                                 token=toks[:, i], pos=jnp.int32(i))
    v = cfg.vocab_size
    np.testing.assert_allclose(np.asarray(lg)[:, :v],
                               np.asarray(logits_pf)[:, :v],
                               atol=2e-3, rtol=2e-3)
