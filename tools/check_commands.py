#!/usr/bin/env python3
"""Command-contract smoke: emit, validate, and round-trip every policy.

    PYTHONPATH=src python tools/check_commands.py [--reqs N] [--seed N]

For every registered policy this drives a small closed-loop
`DramSim.run_ticks` matrix (n_ranks x n_subarrays), emits the DFI-style
command trace, runs the JEDEC sequencing validator
(`repro.core.commands.validate_trace`), and checks the emit -> replay
round trip is bit-identical. One batched-sweep cell cross-checks that
the sweep backend emits the identical trace.

Exit status: 0 when every trace is violation-free and every round trip
is bit-identical, 1 otherwise. Designed to finish in well under a
minute — it is the CI `command-contract` job.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.core.commands import round_trip, traces_equal, validate_trace  # noqa: E402
from repro.core.policy import list_policies  # noqa: E402
from repro.core.refresh import DramSim, make_closed_workload  # noqa: E402
from repro.core.refresh.timing import timing_for_density  # noqa: E402
from repro.core.sweep import SweepSpec, sweep  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="check_commands.py")
    ap.add_argument("--reqs", type=int, default=64)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args(argv)

    t0 = time.time()
    problems = []
    n_traces = n_cmds = 0
    for policy in list_policies():
        for scenario in ("closed_mixed", "closed_write_heavy"):
            for n_ranks, n_subarrays in ((1, 1), (2, 4)):
                label = f"{policy}/{scenario}/R{n_ranks}S{n_subarrays}"
                T = timing_for_density(32, n_ranks=n_ranks,
                                       n_subarrays=n_subarrays)
                wl = make_closed_workload(scenario, args.reqs, args.seed)
                res = DramSim(T, wl, policy).run_ticks(record_commands=True)
                n_traces += 1
                n_cmds += len(res.commands)
                vio = validate_trace(res.commands, limit=3)
                if vio:
                    problems.append(f"{label}: {vio[0]}")
                    continue
                _, bit_identical = round_trip(res.commands)
                if not bit_identical:
                    problems.append(f"{label}: round trip not bit-identical")

    # one sweep cell: the batched backend must emit the identical trace
    spec = SweepSpec(policies=("dsarp",), scenarios=("closed_mixed",),
                     densities=(32,), reqs=args.reqs, seed=args.seed,
                     n_ranks=2, mode="closed")
    swept = sweep(spec, "batched", record_commands=True)
    tr = swept.commands_for("dsarp", "closed_mixed", 32)
    wl = make_closed_workload("closed_mixed", args.reqs, args.seed)
    ref = DramSim(timing_for_density(32, n_ranks=2), wl, "dsarp").run_ticks(
        record_commands=True).commands
    if validate_trace(tr, limit=3):
        problems.append("sweep cell: emitted trace has violations")
    if not traces_equal(tr, ref):
        problems.append("sweep cell: batched emission != run_ticks emission")

    for p in problems:
        print(f"FAIL {p}")
    status = "FAILED" if problems else "ok"
    print(f"check_commands: {n_traces} traces, {n_cmds} commands, "
          f"{len(problems)} problem(s), {time.time() - t0:.1f}s ({status})")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
