#!/usr/bin/env python3
"""Run the `repro.analysis` contract checks and report findings.

Usage:
    python tools/check_contract.py --all              # every pass (default)
    python tools/check_contract.py --pass bitfield --pass dtype
    python tools/check_contract.py --list             # pass/rule catalog
    python tools/check_contract.py --root tests/fixtures/analysis/badrepo

Exit status: 0 when no findings survive pragma suppression, 1 otherwise,
2 on usage errors. Stdlib-only (no numpy/jax) so CI can run it in
seconds before the heavyweight jobs.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import RepoContext, list_passes, run_passes  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="check_contract.py",
        description="Static contract checks for the refresh repo.")
    ap.add_argument("--all", action="store_true",
                    help="run every registered pass (the default)")
    ap.add_argument("--pass", dest="passes", action="append", default=[],
                    metavar="NAME", help="run one pass (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list passes and their rule ids, then exit")
    ap.add_argument("--root", default=str(REPO_ROOT),
                    help="repo root to analyze (default: this checkout)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print pragma-suppressed findings")
    args = ap.parse_args(argv)

    if args.list:
        for info in list_passes():
            print(f"{info.name}: {info.doc.splitlines()[0]}")
            for rid, summary in info.rules:
                print(f"  {rid}  {summary}")
        return 0

    if args.passes and args.all:
        ap.error("--all and --pass are mutually exclusive")
    names = args.passes or None
    try:
        result = run_passes(RepoContext(args.root), names)
    except KeyError as exc:
        print(f"error: {exc.args[0]}", file=sys.stderr)
        return 2

    for f in result.findings:
        print(f)
    if args.show_suppressed:
        for f, pragma in result.suppressed:
            reason = pragma.reason or "(no reason given)"
            print(f"suppressed: {f}  [{reason}]")

    n, s = len(result.findings), len(result.suppressed)
    ran = ", ".join(names) if names else "all passes"
    print(f"check_contract: {ran}: {n} finding(s), {s} suppressed")
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
