#!/usr/bin/env python3
"""Markdown link checker for README + docs/ (no third-party deps).

Collects every inline markdown link/image target from the given files
(default: README.md, ROADMAP.md, docs/*.md), resolves relative targets
against the containing file, and fails if any pointed-to file is missing.
External (http/https/mailto) targets are skipped — CI must not depend on
network. Run from anywhere:

    python tools/check_links.py [files...]
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
#: inline links/images: [text](target) — stops at closing paren/space
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def targets(md_path: str):
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    # drop fenced code blocks: example links in code are not contracts
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK.finditer(text):
        yield m.group(1)


def main(argv: list[str]) -> int:
    files = argv or (["README.md", "ROADMAP.md"]
                     + sorted(glob.glob(os.path.join(REPO, "docs", "*.md"))))
    missing = []
    checked = 0
    for f in files:
        path = f if os.path.isabs(f) else os.path.join(REPO, f)
        if not os.path.exists(path):
            missing.append((f, "<file itself missing>"))
            continue
        base = os.path.dirname(path)
        for tgt in targets(path):
            if tgt.startswith(_SKIP):
                continue
            checked += 1
            rel = tgt.split("#", 1)[0]
            if not rel:
                continue
            dest = os.path.normpath(os.path.join(base, rel))
            if not os.path.exists(dest):
                missing.append((os.path.relpath(path, REPO), tgt))
    if missing:
        print("BROKEN LINKS:")
        for src, tgt in missing:
            print(f"  {src}: {tgt}")
        return 1
    print(f"link-check OK: {checked} relative links across "
          f"{len(files)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
