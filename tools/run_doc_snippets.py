#!/usr/bin/env python3
"""Doc-tested code blocks: extract and EXECUTE the fenced ```python
blocks in the docs, so worked examples can never silently rot (the docs
analogue of tools/check_links.py; both run in the CI docs job).

    PYTHONPATH=src python tools/run_doc_snippets.py [files...]

Default files: docs/policy-cookbook.md and docs/tick-contract.md — the
two documents whose examples are normative (the policy recipe and the
tick-contract spec).

Execution contract:
  * Blocks of one file run IN ORDER in ONE shared namespace, like a
    doctest session — later blocks may use names defined by earlier ones
    (the cookbook's `GreedyPolicy` flows from registration to sweep).
  * A block whose fence info string contains ``no-run`` (i.e.
    ```python no-run) is syntax-checked with compile() but not executed
    — for fragments that illustrate an API against objects the doc
    never constructs (e.g. a live serving engine).
  * After a file's blocks finish, any zero-argument ``test_*`` callables
    the blocks defined are invoked — doc examples that look like tests
    are run as tests.
  * Failures report the file, the block's line number, and the
    traceback, and the tool exits non-zero.
"""
from __future__ import annotations

import inspect
import os
import re
import sys
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_FILES = ["docs/policy-cookbook.md", "docs/tick-contract.md"]

#: fenced block: ```python[ info...] ... ``` (captures info + body)
_FENCE = re.compile(r"^```python([^\n]*)\n(.*?)^```\s*$",
                    re.S | re.M)
#: indented python fences are NOT matched above — fail loudly instead of
#: silently skipping them (rot-proofing is the whole point of this tool)
_INDENTED_FENCE = re.compile(r"^[ \t]+```python", re.M)


def blocks(md_path: str):
    """Yield (line_number, info, source) per ```python block. Raises on
    indented ```python fences, which the executor cannot see."""
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    m = _INDENTED_FENCE.search(text)
    if m:
        line = text[:m.start()].count("\n") + 1
        raise ValueError(
            f"{md_path}:{line}: indented ```python fence would be "
            "silently skipped — outdent it to column 0 (or use a "
            "non-python info string for illustrative fragments)")
    for m in _FENCE.finditer(text):
        line = text[:m.start()].count("\n") + 2   # first line of the body
        yield line, m.group(1).strip(), m.group(2)


def run_file(path: str) -> int:
    """Execute one document's blocks; returns the number of failures."""
    rel = os.path.relpath(path, REPO)
    ns: dict = {"__name__": f"docsnippet:{rel}"}
    failures = 0
    n_run = n_skipped = 0
    try:
        found = list(blocks(path))
    except ValueError as e:
        print(f"FAIL {e}")
        return 1
    for line, info, src in found:
        label = f"{rel}:{line}"
        try:
            code = compile(src, label, "exec")
        except SyntaxError:
            print(f"FAIL {label} (syntax)")
            traceback.print_exc()
            failures += 1
            continue
        if "no-run" in info:
            n_skipped += 1
            continue
        try:
            exec(code, ns)
            n_run += 1
        except Exception:
            print(f"FAIL {label}")
            traceback.print_exc()
            failures += 1
    # doc examples that look like tests are run as tests
    for name, fn in sorted(ns.items()):
        if not name.startswith("test_") or not callable(fn):
            continue
        try:
            if inspect.signature(fn).parameters:
                continue                      # parametrized: defined only
        except (TypeError, ValueError):
            continue
        try:
            fn()
            n_run += 1
        except Exception:
            print(f"FAIL {rel}::{name}()")
            traceback.print_exc()
            failures += 1
    status = "ok" if not failures else f"{failures} FAILED"
    print(f"{rel}: {n_run} executed, {n_skipped} syntax-only ({status})")
    return failures


def main(argv: list[str]) -> int:
    files = argv or DEFAULT_FILES
    failures = 0
    for f in files:
        path = f if os.path.isabs(f) else os.path.join(REPO, f)
        if not os.path.exists(path):
            print(f"MISSING {f}")
            failures += 1
            continue
        failures += run_file(path)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(REPO, "src"))
    sys.exit(main(sys.argv[1:]))
